"""Temporal delta serving: band diffing, output cache, splice parity.

Fast tier: digest/dilation/slab geometry units (cross-checked against
``core.fusion.halo_slabs``, the one true halo geometry), OutputBandCache
LRU + pin semantics, the ``verify_delta_cover`` plan_check rule,
partial-band dispatch plumbing (``submit_bands`` -> ``band_subset``),
the DeltaSession parity matrix on the tilted backend, stream cleanup
leak tests, and the registry error-message satellite.  Slow tier:
kernel-backend delta parity (interpret-mode Pallas) and the mesh
subprocess parity proof.
"""

import asyncio

import jax
import numpy as np
import pytest

from repro import engine
from repro.analysis.plan_check import verify_delta_cover
from repro.core.fusion import halo_slabs
from repro.engine.server import RequestCancelledError, SRServer
from repro.engine.temporal import (
    DeltaSession,
    OutputBandCache,
    band_bounds,
    band_digest,
    band_digests,
    band_input_rows,
    band_slabs,
    changed_bands,
    dilate_dirty,
    halo_reach,
    window_digest,
    window_rows,
)
from repro.models.abpn import ABPNConfig, init_abpn
from repro.models.registry import get_sr_model

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(2), CFG)
LR = (24, 16, 3)          # band_rows=6 -> 4 bands; halo reach ceil(7/6)=2
BAND_ROWS = 6
L = CFG.num_layers

RNG = np.random.default_rng(7)
FRAME = RNG.random(LR, dtype=np.float32)


def make_session(**kw):
    kw.setdefault("backend", "tilted")
    kw.setdefault("band_rows", BAND_ROWS)
    kw.setdefault("autotune", "off")
    return engine.SRSession(LAYERS, **kw)


def clip_with_motion(frames: int = 4) -> list:
    """f0, f0 again (static), one-band change, then a fresh frame."""
    clip = [FRAME.copy(), FRAME.copy()]
    f2 = FRAME.copy()
    f2[2 * BAND_ROWS : 2 * BAND_ROWS + 2] += 0.25  # band 2 only
    clip.append(f2)
    clip.append(RNG.random(LR, dtype=np.float32))
    return clip[:frames]


# ----------------------------------------------------------------------
# band_diff: digests, dilation, geometry
# ----------------------------------------------------------------------
def test_halo_reach():
    assert halo_reach(60, 7, "halo") == 1     # the paper's design point
    assert halo_reach(7, 7, "halo") == 1
    assert halo_reach(6, 7, "halo") == 2
    assert halo_reach(3, 7, "halo") == 3
    assert halo_reach(6, 7, "zero") == 0
    assert halo_reach(6, 7, "replicate") == 0


def test_band_digest_localises_changes():
    own = band_digests(FRAME, BAND_ROWS)
    assert len(own) == LR[0] // BAND_ROWS
    bumped = FRAME.copy()
    bumped[BAND_ROWS + 1, 3] += 1.0  # one pixel inside band 1
    assert changed_bands(band_digests(bumped, BAND_ROWS), own) == {1}
    assert changed_bands(own, own) == set()


def test_digest_folds_dtype():
    # same raw bytes under a different dtype must not collide
    zeros32 = np.zeros((BAND_ROWS, 4, 1), np.float32)
    zeros_i = np.zeros((BAND_ROWS, 4, 1), np.int32)
    assert zeros32.tobytes() == zeros_i.tobytes()
    assert band_digest(zeros32, BAND_ROWS, 0) != band_digest(
        zeros_i, BAND_ROWS, 0)


def test_band_digests_rejects_ragged_height():
    with pytest.raises(ValueError, match="not a multiple"):
        band_digests(FRAME, 7)


def test_changed_bands_rejects_band_count_change():
    with pytest.raises(ValueError, match="digest count changed"):
        changed_bands(band_digests(FRAME, BAND_ROWS),
                      band_digests(FRAME, 12))


def test_dilate_dirty_clips_and_validates():
    # reach 2 at R=6, L=7: band 1 dirties [0, 3]; band 3 dirties [1, 3]
    assert dilate_dirty({1}, 4, BAND_ROWS, L, "halo") == {0, 1, 2, 3}
    assert dilate_dirty({3}, 4, BAND_ROWS, L, "halo") == {1, 2, 3}
    assert dilate_dirty({2}, 4, BAND_ROWS, L, "zero") == {2}
    assert dilate_dirty(set(), 4, BAND_ROWS, L, "halo") == set()
    with pytest.raises(ValueError, match="out of range"):
        dilate_dirty({4}, 4, BAND_ROWS, L, "halo")


def test_dilation_invariant_protects_clean_windows():
    """The invariant the splice relies on: a band OUTSIDE the dilated
    dirty set has a byte-identical receptive-field window."""
    h = LR[0]
    num_bands = h // BAND_ROWS
    for policy in ("zero", "halo", "replicate"):
        for changed in range(num_bands):
            bumped = FRAME.copy()
            bumped[changed * BAND_ROWS] += 1.0
            dirty = dilate_dirty({changed}, num_bands, BAND_ROWS, L, policy)
            for b in range(num_bands):
                if b in dirty:
                    continue
                assert window_digest(
                    FRAME, BAND_ROWS, L, b, policy
                ) == window_digest(bumped, BAND_ROWS, L, b, policy), (
                    f"clean band {b} window changed ({policy}, "
                    f"changed={changed})"
                )


def test_window_rows_halo_widens_and_clips():
    assert window_rows(24, 6, 7, 0, "halo") == (0, 13)
    assert window_rows(24, 6, 7, 2, "halo") == (5, 24)
    assert window_rows(24, 6, 7, 1, "zero") == (6, 12)


def test_band_slabs_and_bounds_mirror_halo_slabs():
    """The host marshalling must be byte-identical to the device-side
    ``core.fusion.halo_slabs`` geometry — the bit-exact splice guarantee
    starts at this equality."""
    ref_slabs, ref_bounds = halo_slabs(FRAME[None], BAND_ROWS, L)
    all_bands = list(range(LR[0] // BAND_ROWS))
    mine = band_slabs(FRAME, BAND_ROWS, L, all_bands, "halo")
    np.testing.assert_array_equal(mine, np.asarray(ref_slabs))
    bounds = band_bounds(LR[0], BAND_ROWS, L, all_bands)
    np.testing.assert_array_equal(bounds, np.asarray(ref_bounds))
    # a subset picks exactly those rows of the full marshalling
    subset = [0, 2]
    np.testing.assert_array_equal(
        band_slabs(FRAME, BAND_ROWS, L, subset, "halo"),
        np.asarray(ref_slabs)[subset])
    # padded slots are all-phantom (0, 0): never read back
    padded = band_bounds(LR[0], BAND_ROWS, L, subset, slots=4)
    assert padded.shape == (4, 2)
    np.testing.assert_array_equal(padded[2:], 0)
    # zero/replicate slabs are the plain band rows
    assert band_input_rows(BAND_ROWS, L, "zero") == BAND_ROWS
    np.testing.assert_array_equal(
        band_slabs(FRAME, BAND_ROWS, L, [1], "zero")[0],
        FRAME[BAND_ROWS : 2 * BAND_ROWS])


# ----------------------------------------------------------------------
# OutputBandCache
# ----------------------------------------------------------------------
def band_value(seed: int, nbytes: int = 1024) -> np.ndarray:
    return np.full(nbytes // 4, float(seed), np.float32)


def test_cache_lru_eviction_bound():
    cache = OutputBandCache(max_bytes=2048)
    cache.put("a", band_value(1))
    cache.put("b", band_value(2))
    assert cache.get("a") is not None  # refresh: "b" is now LRU
    cache.put("c", band_value(3))
    s = cache.stats()
    assert s["bytes"] <= 2048 and s["evictions"] == 1
    assert cache.peek("b") is None and cache.peek("a") is not None


def test_cache_put_copies_and_dedupes():
    cache = OutputBandCache(max_bytes=1 << 20)
    src = band_value(1)
    cache.put("k", src)
    src[:] = -1.0  # mutating the source must not reach the cache
    np.testing.assert_array_equal(cache.get("k"), band_value(1))
    cache.put("k", band_value(9))  # same key: no-op, same bytes by contract
    assert cache.stats()["puts"] == 1
    np.testing.assert_array_equal(cache.peek("k"), band_value(1))


def test_cache_pins_block_eviction():
    cache = OutputBandCache(max_bytes=1024)
    cache.put("a", band_value(1))
    cache.pin("a")
    cache.put("b", band_value(2))  # over budget: the unpinned "b" goes
    assert cache.peek("a") is not None and cache.peek("b") is None
    # pin=True is atomic with the insert: the entry survives the
    # eviction pass its own insert triggers
    cache.put("b", band_value(2), pin=True)
    cache.put("c", band_value(3))
    s = cache.stats()
    assert cache.peek("a") is not None and cache.peek("b") is not None
    assert s["bytes"] > s["max_bytes"] and s["pinned"] == 2  # visible overrun
    cache.unpin("a")
    cache.unpin("b")  # back to evictable -> budget enforced again
    assert cache.stats()["bytes"] <= 1024
    assert cache.pinned == 0


def test_cache_pin_errors():
    cache = OutputBandCache(max_bytes=1024)
    with pytest.raises(KeyError):
        cache.pin("missing")
    cache.put("a", band_value(1))
    with pytest.raises(ValueError, match="unbalanced"):
        cache.unpin("a")
    with pytest.raises(ValueError, match="positive"):
        OutputBandCache(max_bytes=0)


def test_cache_counters():
    cache = OutputBandCache(max_bytes=1 << 20)
    assert cache.get("a") is None
    cache.put("a", band_value(1))
    cache.get("a")
    cache.peek("a")  # peek is uncounted
    s = cache.stats()
    assert (s["hits"], s["misses"]) == (1, 1)
    assert s["hit_rate"] == 0.5
    assert s["bytes_saved"] == band_value(1).nbytes
    # get(pin=True) pins atomically with the hit; a miss pins nothing
    assert cache.get("a", pin=True) is not None
    assert cache.pinned == 1
    assert cache.get("missing", pin=True) is None
    cache.unpin("a")
    assert cache.pinned == 0


# ----------------------------------------------------------------------
# plan_check: the splice invariant rule
# ----------------------------------------------------------------------
def delta_plan(policy="halo"):
    return engine.make_plan(LAYERS, LR, band_rows=BAND_ROWS,
                            backend="tilted", vertical_policy=policy)


def test_verify_delta_cover_accepts_valid_partition():
    assert verify_delta_cover(delta_plan(), [1, 2, 3],
                              changed_bands=[3]) == []
    assert verify_delta_cover(delta_plan("zero"), [2],
                              changed_bands=[2]) == []
    assert verify_delta_cover(delta_plan(), []) == []  # nothing changed


def test_verify_delta_cover_flags_bad_sets():
    dup = verify_delta_cover(delta_plan(), [1, 1, 2])
    assert [f.rule for f in dup] == ["delta_cover"]
    oob = verify_delta_cover(delta_plan(), [4])
    assert [f.rule for f in oob] == ["delta_cover"]
    assert all(f.severity == "error" for f in dup + oob)


def test_verify_delta_cover_flags_missing_dilation():
    # band 3 changed, reach 2 -> bands 1..3 must be dirty; {3} is stale
    stale = verify_delta_cover(delta_plan(), [3], changed_bands=[3])
    assert "delta_dilation" in [f.rule for f in stale]
    # zero policy: reach 0, {3} alone is fine
    assert verify_delta_cover(delta_plan("zero"), [3],
                              changed_bands=[3]) == []


# ----------------------------------------------------------------------
# submit_bands: partial dispatches through the scheduler
# ----------------------------------------------------------------------
def test_submit_bands_matches_full_upscale_rows():
    session = make_session(vertical_policy="halo")
    with SRServer({"abpn": session}) as server:
        full = np.asarray(session.upscale(FRAME))
        plan = session.plan_for(LR)
        subset = [0, 2]
        slabs = band_slabs(FRAME, BAND_ROWS, L, subset, "halo")
        out = np.asarray(server.submit_bands(
            slabs, subset, plan=plan).result())
        hr = BAND_ROWS * plan.scale
        for i, b in enumerate(subset):
            np.testing.assert_array_equal(
                out[i], full[b * hr : (b + 1) * hr])
        # the dispatch is tagged as a band subset in the scheduler log
        recent = server.scheduler_stats()["recent_dispatches"]
        assert recent[-1]["bands"] == list(subset)


def test_submit_bands_validation():
    session = make_session(vertical_policy="halo")
    with SRServer({"abpn": session}) as server:
        plan = session.plan_for(LR)
        slabs = band_slabs(FRAME, BAND_ROWS, L, [0, 1], "halo")
        with pytest.raises(ValueError, match="strictly increasing"):
            server.submit_bands(slabs, [1, 0], plan=plan)
        with pytest.raises(ValueError, match="range"):
            server.submit_bands(slabs, [3, 4], plan=plan)
        with pytest.raises(ValueError):
            server.submit_bands(slabs[:, :-1], [0, 1], plan=plan)


def test_cancel_fails_future_and_releases_queue():
    session = make_session()
    with SRServer({"abpn": session}) as server:
        fut = server.submit(FRAME[None])
        assert server.cancel(fut) is True
        assert isinstance(fut.exception(), RequestCancelledError)
        g = server.scheduler_stats()
        assert g["pending_frames"] == 0 and g["carry_buckets"] == 0
        # a resolved future cannot be cancelled
        done = server.submit(FRAME[None])
        done.result()
        assert server.cancel(done) is False


# ----------------------------------------------------------------------
# DeltaSession: parity + reuse
# ----------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["zero", "halo", "replicate"])
def test_delta_session_bit_exact_and_reuses(policy):
    session = make_session(vertical_policy=policy)
    clip = clip_with_motion()
    with DeltaSession(session) as ds:
        for frame in clip:
            out = ds.serve(frame)
            np.testing.assert_array_equal(
                out, np.asarray(session.upscale(frame)))
    t = session.temporal_stats()
    assert t["frames"] == len(clip)
    assert t["bands_skipped"] > 0 and 0 < t["reuse_ratio"] < 1
    assert t["band_rows_served"] < t["band_rows_total"]
    assert t["band_rows_dispatched"] == t["band_rows_served"]
    assert t["cover_violations"] == 0
    assert t["cache"]["hits"] == t["bands_skipped"]
    # the static frame reused EVERYTHING: frame 1 served 0 bands
    num_bands = LR[0] // BAND_ROWS
    assert t["bands_skipped"] >= num_bands
    # stats() exposes the section once delta frames were served
    assert session.stats()["temporal"]["frames"] == len(clip)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["zero", "halo"])
def test_delta_session_kernel_backend_bit_exact(policy):
    # a shallow stack keeps interpret-mode Pallas time bounded
    cfg = ABPNConfig(num_layers=3)
    layers = init_abpn(jax.random.PRNGKey(4), cfg)
    session = engine.SRSession(layers, backend="kernel", band_rows=6,
                               vertical_policy=policy, autotune="off")
    clip = [FRAME.copy(), FRAME.copy(), clip_with_motion(3)[2]]
    with DeltaSession(session) as ds:
        for frame in clip:
            np.testing.assert_array_equal(
                ds.serve(frame), np.asarray(session.upscale(frame)))
    assert session.temporal_stats()["bands_skipped"] > 0


def test_delta_session_rejects_reference_backend():
    session = engine.SRSession(LAYERS, backend="reference", autotune="off")
    with pytest.raises(ValueError, match="banded backend"):
        DeltaSession(session)
    ref_plan = engine.make_plan(LAYERS, LR, band_rows=BAND_ROWS,
                                backend="reference")
    with pytest.raises(ValueError, match="reference"):
        make_session().band_executor_for(ref_plan, 1, np.float32)


def test_delta_session_plan_switch_resets_state():
    session = make_session(vertical_policy="halo")
    small = RNG.random((12, 16, 3), dtype=np.float32)
    with DeltaSession(session) as ds:
        ds.serve(FRAME)
        out = ds.serve(small)  # resolution switch mid-stream
        np.testing.assert_array_equal(
            out, np.asarray(session.upscale(small)))
        # pins now belong to the new plan's bands only
        assert session.output_cache().pinned == 12 // BAND_ROWS
        # and returning to the first resolution serves full (state reset)
        np.testing.assert_array_equal(
            ds.serve(FRAME), np.asarray(session.upscale(FRAME)))
    assert session.output_cache().pinned == 0


def test_delta_session_close_semantics():
    session = make_session()
    ds = DeltaSession(session)
    ds.serve(FRAME)
    ds.close()
    ds.close()  # idempotent
    assert session.output_cache().pinned == 0
    with pytest.raises(RuntimeError, match="closed"):
        ds.serve(FRAME)


def test_delta_session_survives_external_cache_eviction():
    # a cache too small to hold even one frame's bands: every "clean"
    # band misses residency and is re-served — pure cost, still exact
    session = make_session(vertical_policy="zero")
    with DeltaSession(session, cache_bytes=1024) as ds:
        for frame in clip_with_motion(3):
            np.testing.assert_array_equal(
                ds.serve(frame), np.asarray(session.upscale(frame)))
    assert session.temporal_stats()["cover_violations"] == 0


# ----------------------------------------------------------------------
# stream(delta=True) + abandoned-stream cleanup
# ----------------------------------------------------------------------
def test_stream_delta_end_to_end():
    session = make_session(vertical_policy="halo")
    clip = clip_with_motion()
    with SRServer({"abpn": session}) as server:
        async def run():
            outs = []
            async for hr in server.stream(clip, delta=True):
                outs.append(hr)
            return outs

        outs = asyncio.run(run())
    refs = np.asarray(session.upscale(np.stack(clip)))
    assert len(outs) == len(clip)
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)
    t = session.temporal_stats()
    assert t["frames"] == len(clip) and t["bands_skipped"] > 0


@pytest.mark.parametrize("delta", [False, True])
def test_abandoned_stream_releases_resources(delta):
    """aclose() after one frame must leave no queued frames, no pinned
    carry buckets, and (delta) no pinned cache entries behind."""
    session = make_session(vertical_policy="halo")
    clip = [FRAME.copy() for _ in range(6)]
    with SRServer({"abpn": session}) as server:
        async def run():
            gen = server.stream(clip, delta=delta, lookahead=4)
            async for _ in gen:
                break  # abandon after the first frame
            await gen.aclose()

        asyncio.run(run())
        g = server.scheduler_stats()
        assert g["pending_frames"] == 0
        assert g["carry_buckets"] == 0
        assert g["inflight_dispatches"] == 0
    if delta:
        assert session.output_cache().pinned == 0


# ----------------------------------------------------------------------
# satellites: registry error, mesh parity (subprocess)
# ----------------------------------------------------------------------
def test_registry_unknown_model_lists_names_and_suggests():
    with pytest.raises(ValueError) as exc:
        get_sr_model("abpn-3x")
    msg = str(exc.value)
    assert "abpn_x3" in msg          # canonical names listed
    assert "abpn-x3" in msg          # aliases listed
    assert "did you mean 'abpn-x3'" in msg
    with pytest.raises(ValueError) as exc2:
        get_sr_model("totally_unknown")
    assert "registered" in str(exc2.value)


@pytest.mark.slow
def test_delta_parity_on_mesh_session_subprocess(subproc):
    """Delta serving on a band-sharded mesh session: partial dispatches
    run locally, the guarantee vs the SHARDED full path holds because
    sharded full re-upscale is itself bit-exact vs single-device."""
    out = subproc("""
        import jax, numpy as np
        from repro import engine
        from repro.engine.temporal import DeltaSession
        from repro.models.abpn import ABPNConfig, init_abpn

        layers = init_abpn(jax.random.PRNGKey(2), ABPNConfig())
        session = engine.SRSession(
            layers, backend="tilted", vertical_policy="halo",
            band_rows=6, mesh=(2, 2), autotune="off")
        rng = np.random.default_rng(7)
        base = rng.random((24, 16, 3), dtype=np.float32)
        moved = base.copy(); moved[12:14] += 0.25
        clip = [base, base.copy(), moved]
        exact = True
        with DeltaSession(session) as ds:
            for f in clip:
                exact &= np.array_equal(
                    ds.serve(f), np.asarray(session.upscale(f)))
        t = session.temporal_stats()
        assert t["bands_skipped"] > 0, t
        print("MESH_DELTA_OK", exact, t["reuse_ratio"])
    """)
    assert "MESH_DELTA_OK True" in out
