"""Production traffic hardening: per-request deadlines and cancellation,
admission="shed" load-shedding, degrade-under-pressure (DegradePolicy),
fault injection through the server's launch path, and concurrent
admission="reject" behavior.  All fast tier (tiny tilted shapes).
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro import engine
from repro.engine.scheduler import (
    DeadlineExceededError,
    MicroBatchScheduler,
    QueueFullError,
    RequestShedError,
    SchedRequest,
)
from repro.engine.server import DEGRADE_LADDER, DegradePolicy, SRFuture, SRServer
from repro.models.abpn import ABPNConfig, init_abpn
from repro.runtime.resilience import FailureInjector, InjectedFailure

CFG = ABPNConfig()
LAYERS = init_abpn(jax.random.PRNGKey(2), CFG)
LR = (12, 16, 3)
CLIP = jax.random.uniform(jax.random.PRNGKey(21), (8, *LR))
ORACLE = None  # filled lazily (module import must stay cheap)


def oracle(frames):
    global ORACLE
    if ORACLE is None:
        plan = engine.make_plan(LAYERS, LR, band_rows=12, backend="tilted")
        ORACLE = np.asarray(engine.run(plan, LAYERS, CLIP))
    n = frames.shape[0]
    for i in range(CLIP.shape[0] - n + 1):
        if np.array_equal(np.asarray(frames), np.asarray(CLIP[i:i + n])):
            return ORACLE[i:i + n]
    raise AssertionError("frames are not a contiguous CLIP slice")


def make_session(**kw):
    kw.setdefault("backend", "tilted")
    return engine.SRSession(LAYERS, **kw)


def make_server(*, session_kw=None, **server_kw):
    session = make_session(**(session_kw or {}))
    return SRServer({"abpn": session}, **server_kw), session


def sched_req(n, *, seq=0, priority=0, deadline=None, served=0):
    """A scheduler-only request (no session/plan/future needed) for unit
    tests of expiry and shed selection."""
    r = SchedRequest(
        seq=seq, key=("m", "plan", "float32"), session=None, plan=None,
        flat=None, n=n, priority=priority, future=None, ndim=4, lead=None,
        deadline=deadline,
    )
    r.served = served
    return r


# ----------------------------------------------------------------------
# Deadlines: scheduler-level expiry semantics
# ----------------------------------------------------------------------
def test_expire_due_removes_only_queued_due_requests():
    s = MicroBatchScheduler()
    fresh = sched_req(2, seq=0, deadline=100.0)
    due = sched_req(2, seq=1, deadline=5.0)
    no_deadline = sched_req(2, seq=2)
    for r in (fresh, due, no_deadline):
        s.add(r)
    expired = s.expire_due(now=10.0)
    assert expired == [due]
    assert s.pending_frames == 4
    assert s.stats()["expired"] == 1
    # idempotent: nothing else is due
    assert s.expire_due(now=10.0) == []


def test_expire_due_spares_partially_served_requests():
    """Frames already handed to a dispatch are past recall: a half-served
    clip completes even if its deadline passes mid-flight."""
    s = MicroBatchScheduler()
    partial = sched_req(4, deadline=5.0, served=2)
    s.add(partial)
    assert s.expire_due(now=10.0) == []
    assert s.pending_frames == 4  # untouched


def test_shed_victims_picks_lowest_priority_latest_deadline():
    s = MicroBatchScheduler()
    low_late = sched_req(2, seq=0, priority=0)            # no deadline: latest
    low_soon = sched_req(2, seq=1, priority=0, deadline=5.0)
    high = sched_req(2, seq=2, priority=5, deadline=50.0)
    for r in (low_late, low_soon, high):
        s.add(r)
    # newcomer at priority 1: both priority-0 requests rank below it; the
    # deadline-less one is WORST and sheds first
    victims = s.shed_victims(2, priority=1, deadline=None)
    assert victims == [low_late]
    assert s.stats()["shed"] == 1
    assert s.pending_frames == 4
    # needing more frames takes the next-worst too
    victims = s.shed_victims(2, priority=1, deadline=None)
    assert victims == [low_soon]
    # nothing ranks below priority 1 anymore -> newcomer loses, queue intact
    assert s.shed_victims(2, priority=1, deadline=None) is None
    assert s.pending_frames == 2 and s.stats()["shed"] == 2


def test_shed_victims_equal_priority_breaks_on_deadline():
    s = MicroBatchScheduler()
    urgent = sched_req(2, seq=0, priority=0, deadline=5.0)
    relaxed = sched_req(2, seq=1, priority=0, deadline=50.0)
    s.add(urgent)
    s.add(relaxed)
    # newcomer with a deadline between the two: only the later-deadline
    # queued request ranks below it
    victims = s.shed_victims(2, priority=0, deadline=10.0)
    assert victims == [relaxed]
    # the earlier-deadline request never ranks below this newcomer
    assert s.shed_victims(2, priority=0, deadline=10.0) is None


def test_shed_victims_never_touches_partially_served():
    s = MicroBatchScheduler()
    partial = sched_req(4, seq=0, priority=0, served=1)
    s.add(partial)
    assert s.shed_victims(1, priority=9, deadline=None) is None


# ----------------------------------------------------------------------
# Deadlines: server behavior
# ----------------------------------------------------------------------
def test_queued_deadline_expiry_spares_coalesced_neighbor():
    """The acceptance scenario: a request expires while QUEUED; the
    same-key request it would have coalesced with completes bit-exact."""
    server, _ = make_server(session_kw={"max_bucket": 4})
    keeper = server.submit(CLIP[:2])
    doomed = server.submit(CLIP[2:4], timeout=0.02)
    time.sleep(0.06)
    out = keeper.result()  # drives the drain; expiry runs first
    np.testing.assert_array_equal(np.asarray(out), oracle(CLIP[:2]))
    with pytest.raises(DeadlineExceededError):
        doomed.result()
    s = server.scheduler_stats()
    assert s["expired"] == 1
    # the survivor dispatched alone: the expired frames left the queue
    # BEFORE bucket sizing, so they never inflated the dispatch
    assert s["dispatches"] == 1
    assert s["recent_dispatches"][0]["frames"] == 2
    # the server keeps serving afterwards
    np.testing.assert_array_equal(
        np.asarray(server.submit(CLIP[4:6]).result()), oracle(CLIP[4:6]))


def test_dead_on_arrival_fails_before_any_work():
    server, session = make_server()
    fut = server.submit(CLIP[:2], timeout=0.0)
    assert fut.done()
    with pytest.raises(DeadlineExceededError):
        fut.result()
    assert server.scheduler_stats()["expired"] == 1
    assert server.scheduler_stats()["dispatches"] == 0
    assert session.cache_stats()["entries"] == []  # nothing compiled


def test_deadline_and_timeout_are_exclusive():
    server, _ = make_server()
    with pytest.raises(ValueError, match="not both"):
        server.submit(CLIP[:2], deadline=time.monotonic() + 1, timeout=1)


def test_flush_cancels_expired_work():
    server, _ = make_server()
    fut = server.submit(CLIP[:2], timeout=0.01)
    time.sleep(0.05)
    server.flush()
    assert isinstance(fut.exception(), DeadlineExceededError)


def test_exceptions_are_distinguishable():
    assert issubclass(DeadlineExceededError, TimeoutError)
    assert issubclass(RequestShedError, QueueFullError)
    assert not issubclass(DeadlineExceededError, QueueFullError)


# ----------------------------------------------------------------------
# SRFuture.result(timeout=): wall-clock honored while driving the drain
# ----------------------------------------------------------------------
def test_result_timeout_honored_while_caller_drives_drain():
    """A caller draining a deep queue gets TimeoutError when its budget
    runs out mid-drain — not after the whole queue finishes."""
    injector = FailureInjector(
        delay_dispatches={k: 0.25 for k in range(16)})
    server, _ = make_server(
        session_kw={"max_bucket": 2}, injector=injector)
    fut = server.submit(CLIP[:8])  # 4 dispatches x >= 0.25 s each
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.3)
    elapsed = time.monotonic() - t0
    # must bail after the dispatch it was inside, not drain all four
    assert elapsed < 0.85
    assert not fut.done()
    # the request is NOT cancelled by a wait timeout: it still completes
    np.testing.assert_array_equal(
        np.asarray(fut.result()), oracle(CLIP[:8]))


def test_wait_done_survives_spurious_wakeups():
    """A notify without completion must neither return early nor shorten
    the remaining wait: _wait_done loops on a monotonic deadline."""
    class _FakeServer:
        def _drain_until(self, fut, deadline=None):
            pass  # another thread "owns" the drain

    fut = SRFuture(_FakeServer())
    stop = threading.Event()

    def spam():
        while not stop.is_set():
            with fut._cond:
                fut._cond.notify_all()
            time.sleep(0.005)

    spammer = threading.Thread(target=spam, daemon=True)
    spammer.start()
    try:
        # under-wait guard: spurious wakeups must not break the timeout out
        # early...
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            fut.result(timeout=0.15)
        assert time.monotonic() - t0 >= 0.15
        # ...and a completion mid-wait is returned, not lost
        finisher = threading.Timer(0.1, lambda: fut._finish(result=42))
        finisher.start()
        assert fut.result(timeout=5.0) == 42
    finally:
        stop.set()
        spammer.join()


# ----------------------------------------------------------------------
# admission="shed"
# ----------------------------------------------------------------------
def test_shed_requires_a_bound():
    with pytest.raises(ValueError, match="max_inflight_frames"):
        make_server(admission="shed")


def test_shed_evicts_lower_priority_for_newcomer():
    server, _ = make_server(
        session_kw={"max_bucket": 4},
        max_inflight_frames=4, admission="shed")
    victim = server.submit(CLIP[:4], priority=0)
    keeper = server.submit(CLIP[4:6], priority=1)  # queue full: sheds victim
    assert victim.done()
    with pytest.raises(RequestShedError):
        victim.result()
    # RequestShedError IS a QueueFullError for coarse-grained handlers
    assert isinstance(victim.exception(), QueueFullError)
    np.testing.assert_array_equal(
        np.asarray(keeper.result()), oracle(CLIP[4:6]))
    s = server.scheduler_stats()
    assert s["shed"] == 1 and s["rejected"] == 0


def test_shed_rejects_newcomer_when_it_ranks_lowest():
    server, _ = make_server(
        session_kw={"max_bucket": 4},
        max_inflight_frames=4, admission="shed")
    queued = server.submit(CLIP[:4], priority=1)
    with pytest.raises(QueueFullError):
        server.submit(CLIP[4:6], priority=0)
    s = server.scheduler_stats()
    assert s["rejected"] == 1 and s["shed"] == 0
    # the queued high-priority work is untouched and completes
    np.testing.assert_array_equal(
        np.asarray(queued.result()), oracle(CLIP[:4]))


def test_shed_equal_priority_prefers_deadline_holders():
    server, _ = make_server(
        session_kw={"max_bucket": 4},
        max_inflight_frames=4, admission="shed")
    # no deadline = latest possible deadline = first to shed
    relaxed = server.submit(CLIP[:4], priority=0)
    urgent = server.submit(CLIP[4:6], priority=0, timeout=30.0)
    with pytest.raises(RequestShedError):
        relaxed.result()
    np.testing.assert_array_equal(
        np.asarray(urgent.result()), oracle(CLIP[4:6]))


# ----------------------------------------------------------------------
# DegradePolicy: the ladder itself
# ----------------------------------------------------------------------
def test_degrade_policy_validates():
    with pytest.raises(ValueError):
        DegradePolicy(0.0)
    with pytest.raises(ValueError):
        DegradePolicy(10.0, breach_steps=0)
    with pytest.raises(ValueError):
        DegradePolicy(10.0, recover_fraction=1.5)
    with pytest.raises(ValueError):
        make_server(degrade="not a policy")


def test_degrade_steps_down_ladder_on_sustained_breach():
    p = DegradePolicy(10.0, breach_steps=3)
    for _ in range(2):
        assert p.observe(100.0) is None  # two breaches: not yet
    t = p.observe(100.0)
    assert t is not None and t["reason"] == "slo_breach"
    assert p.level == 1 and t["to_step"] == "bf16"
    for _ in range(3):
        p.observe(100.0)
    assert p.level == 2
    for _ in range(3):
        p.observe(100.0)
    assert p.level == 3  # ladder bottom
    for _ in range(6):
        p.observe(100.0)
    assert p.level == 3  # clamped
    assert [t["to_step"] for t in p.transitions] == list(DEGRADE_LADDER[1:])


def test_degrade_recovers_with_hysteresis():
    p = DegradePolicy(10.0, alpha=0.5, breach_steps=1, recover_steps=3)
    p.observe(100.0)
    p.observe(100.0)
    assert p.level >= 1
    for _ in range(200):
        p.observe(1.0)
    assert p.level == 0
    assert any(t["reason"] == "recovered" for t in p.transitions)
    # hysteresis: a single breach observation does not move the ladder
    p2 = DegradePolicy(10.0, breach_steps=3)
    p2.observe(100.0)
    p2.observe(1.0)
    assert p2.level == 0 and p2.transitions == []


def test_degrade_knobs_follow_level():
    p = DegradePolicy(10.0)
    assert p.serve_dtype(np.float32) == np.float32
    assert p.lookahead(4) == 4 and p.bucket_cap(8) == 8
    p.level = 1
    assert p.serve_dtype(np.float32).name == "bfloat16"
    assert p.serve_dtype(np.int8) == np.int8  # only fp32 downcasts
    assert p.lookahead(4) == 4
    p.level = 2
    assert p.lookahead(4) == 2 and p.lookahead(1) == 1
    assert p.bucket_cap(8) == 8
    p.level = 3
    assert p.bucket_cap(8) == 4 and p.bucket_cap(1) == 1


# ----------------------------------------------------------------------
# DegradePolicy: wired into the server
# ----------------------------------------------------------------------
def test_degrade_ladder_visible_in_server_dispatches():
    """With an unmeetable SLO every completion breaches: dispatch dtype
    flips to bf16, then freshly derived buckets halve — all visible in
    recent_dispatches — and stats() logs every transition."""
    policy = DegradePolicy(1e-6, breach_steps=1)
    server, _ = make_server(
        session_kw={"max_bucket": 4}, degrade=policy)
    out = server.submit(CLIP[:2]).result()  # level 0: served in fp32
    np.testing.assert_array_equal(np.asarray(out), oracle(CLIP[:2]))
    assert policy.level == 1
    out = server.submit(CLIP[:2]).result()  # level 1: dispatches in bf16
    assert str(out.dtype) == "bfloat16"
    d = server.scheduler_stats()["recent_dispatches"][-1]
    assert d["dtype"] == "bfloat16"
    assert policy.level == 2
    server.submit(CLIP[:2]).result()
    assert policy.level == 3
    # level 3: a 4-frame request's fresh bucket (4) halves to 2 -> two
    # dispatches of bucket 2, the tail riding the pinned carry bucket
    server.submit(CLIP[:4]).result()
    buckets = [d["bucket"]
               for d in server.scheduler_stats()["recent_dispatches"][-2:]]
    assert buckets == [2, 2]
    st = server.stats()["degrade"]
    assert st["level"] == 3 and st["step"] == "half_buckets"
    assert len(st["transitions"]) == 3
    assert st["degraded_requests"] >= 1
    assert st["p99_ms"] > st["slo_p99_ms"]


def test_degrade_halves_stream_lookahead():
    policy = DegradePolicy(10.0)
    server, _ = make_server(degrade=policy)
    policy.level = 2
    assert policy.lookahead(4) == 2

    import asyncio

    async def run():
        outs = []
        async for hr in server.stream(list(CLIP[:4]), lookahead=4):
            outs.append(np.asarray(hr))
        return outs

    outs = asyncio.run(run())
    assert len(outs) == 4
    # level 2 also includes the bf16 step, so compare at bf16 tolerance
    np.testing.assert_allclose(
        np.stack(outs).astype(np.float32), oracle(CLIP[:4]),
        rtol=0, atol=1e-2)


# ----------------------------------------------------------------------
# Fault injection through the launch path
# ----------------------------------------------------------------------
def test_injected_dispatch_failure_is_isolated():
    """Failing the k-th dispatch fails exactly that dispatch's requests;
    earlier and later requests complete bit-exact and the server keeps
    serving."""
    injector = FailureInjector(fail_dispatches={1})
    server, _ = make_server(session_kw={"max_bucket": 2}, injector=injector)
    futs = [server.submit(CLIP[2 * i:2 * i + 2]) for i in range(3)]
    server.flush()
    np.testing.assert_array_equal(
        np.asarray(futs[0].result()), oracle(CLIP[:2]))
    with pytest.raises(InjectedFailure):
        futs[1].result()
    np.testing.assert_array_equal(
        np.asarray(futs[2].result()), oracle(CLIP[4:6]))
    assert injector.stats()["injected_failures"] == 1
    # fresh traffic after the fault serves normally
    np.testing.assert_array_equal(
        np.asarray(server.submit(CLIP[6:8]).result()), oracle(CLIP[6:8]))


def test_poisoned_model_fails_only_its_own_traffic():
    injector = FailureInjector(poison_models={"bad"})
    server = SRServer(
        {"good": make_session(), "bad": make_session()},
        injector=injector,
    )
    ok = server.submit(CLIP[:2], model="good")
    doomed = server.submit(CLIP[2:4], model="bad")
    server.flush()
    np.testing.assert_array_equal(np.asarray(ok.result()), oracle(CLIP[:2]))
    with pytest.raises(InjectedFailure, match="poison"):
        doomed.result()
    # the poisoned model fails EVERY time; the good model keeps serving
    with pytest.raises(InjectedFailure):
        server.submit(CLIP[:2], model="bad").result()
    np.testing.assert_array_equal(
        np.asarray(server.submit(CLIP[4:6], model="good").result()),
        oracle(CLIP[4:6]))


def test_injector_requires_on_dispatch():
    with pytest.raises(ValueError, match="on_dispatch"):
        make_server(injector=object())


def test_close_releases_sessions_for_rehosting():
    """A closed server hands its sessions back, warm caches included —
    the load harness re-hosts one warm session set across server
    configurations."""
    session = make_session()
    server = SRServer({"abpn": session})
    np.testing.assert_array_equal(
        np.asarray(server.submit(CLIP[:2]).result()), oracle(CLIP[:2]))
    compiled = session.cache_stats()["entries"]
    server.close()
    successor = SRServer({"abpn": session}, max_inflight_frames=8,
                         admission="shed")
    np.testing.assert_array_equal(
        np.asarray(successor.submit(CLIP[2:4]).result()), oracle(CLIP[2:4]))
    assert session.cache_stats()["entries"] == compiled  # no recompile


# ----------------------------------------------------------------------
# admission="reject" under genuinely concurrent submits
# ----------------------------------------------------------------------
def test_concurrent_reject_no_hangs_no_lost_futures():
    """Thread pool hammering a bounded reject-mode server: every request
    either completes bit-exact or raises QueueFullError."""
    oracle(CLIP[:1])  # build the oracle before threads race the global
    server, _ = make_server(
        session_kw={"max_bucket": 2},
        max_inflight_frames=4, admission="reject")
    threads, outcomes, errs = 6, [], []

    def worker(tid):
        for i in range(5):
            start = (tid + i) % 7
            frames = CLIP[start:start + 2]
            try:
                fut = server.submit(frames)
            except QueueFullError:
                outcomes.append(("rejected", None, None))
                continue
            try:
                hr = fut.result(timeout=60)
            except Exception as e:  # pragma: no cover - diagnostics
                errs.append(e)
                return
            outcomes.append(("ok", start, np.asarray(hr)))

    pool = [threading.Thread(target=worker, args=(t,)) for t in range(threads)]
    for t in pool:
        t.start()
    for t in pool:
        t.join(timeout=120)
        assert not t.is_alive(), "worker hung"
    assert errs == []
    assert len(outcomes) == threads * 5  # no lost futures
    served = [(s, hr) for kind, s, hr in outcomes if kind == "ok"]
    assert served, "at least some requests must be admitted"
    for start, hr in served:
        np.testing.assert_array_equal(hr, oracle(CLIP[start:start + 2]))
    s = server.scheduler_stats()
    assert s["rejected"] == sum(1 for k, _, _ in outcomes if k == "rejected")
    assert s["pending_frames"] == 0 and s["inflight_frames"] == 0
